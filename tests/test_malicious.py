"""Malicious-security tier: MAC'd 2PC shares + ABY3 exact truncation.

Contracts:
  1. MAC PLUMBING — honest partial opens under a `mac_scope` verify
     cleanly (n_opened > 0); flipping ONE bit in either a value
     component or a MAC component of any opened tensor makes the
     batched boundary check abort with `MacCheckError`.
  2. ABORT AT THE BOUNDARY — a full spdz2pc proxy forward with a
     tampered opening aborts at `MPCEngine.entropy_head` (which runs
     the constant-size `mac_check_flight`); the honest forward passes.
  3. PRICING — authenticated mul = MAC'd triple + sacrificed triple
     (offline) + sacrifice flight + beaver open (online); spdz2pc
     truncation pays a dealer MAC'd pair + opening round on BOTH rings
     (local shift is not MAC-preserving); the MAC check itself is
     constant-size.
  4. FORWARD PARITY — all six nonlinearity variants match ClearEngine
     on RING64 under spdz2pc AND aby3trunc, within the same per-variant
     tolerances the semi-honest 2PC path holds.
  5. MIRROR + EXECUTION — costs.proxy_exec_cost mirrors the TraceEngine
     probe record-for-record for both new backends x both rings x
     eager/fused, and an executed WaveExecutor phase passes
     ledger_agrees with the right party axis and clear-match scores.
  6. WRAP STATISTICS (slow) — replicated3pc probabilistic truncation
     measurably wraps at RING32 on large-magnitude values, at a rate
     consistent with the analytic |enc|/2^32 bound; aby3trunc's trunc2
     produces ZERO wraps on the same value stream.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_targets import TINY_TARGET
from repro.core import proxy as proxy_mod
from repro.core.executor import ExecConfig, WaveExecutor
from repro.core.proxy import ProxySpec
from repro.engine import (ClearEngine, MPCEngine, TraceEngine, VARIANTS,
                          abstract_shares, proxy_entropy)
from repro.mpc import costs, ops as mops, protocols
from repro.mpc.comm import ledger_scope
from repro.mpc.protocols.spdz2pc import (MacCheckError, mac_key, mac_scope,
                                         tamper_scope)
from repro.mpc.ring import RING32, RING64, x64_scope
from repro.mpc.sharing import reveal, share

CFG = dataclasses.replace(TINY_TARGET, vocab_size=64, n_layers=1,
                          d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                          d_ff=64)
SPEC = ProxySpec(1, 2, 4)
SEQ, BATCH, CLASSES = 8, 6, 3
K = jax.random.key(0)

# the same per-variant tolerances the semi-honest paths hold
ATOL = {"full": 2e-3, "no-sm": 2e-2, "no-ln": 2e-2, "no-se": 6e-2,
        "quad_sm": 2e-2, "poly_sm": 2e-2}

RINGS = {"ring64": RING64, "ring32": RING32}
MALICIOUS = ("spdz2pc", "aby3trunc")
PARTIES = {"spdz2pc": 4, "aby3trunc": 3}   # share rows (spdz: 2 + 2 MAC)


def _k(i):
    return jax.random.fold_in(K, i)


# ---------------------------------------------------------------------------
# 1. MAC plumbing: honest pass, tampered abort
# ---------------------------------------------------------------------------

class TestMacCheck:
    def test_registry_and_layout(self, x64):
        be = protocols.get("spdz2pc")
        assert be.n_parties == 4
        s = share(_k(0), jnp.array([1.5, -2.25]), RING64, "spdz2pc")
        assert s.sh.shape == (4, 2)
        alpha, _, _ = mac_key(RING64)
        # rows 0+1 reconstruct x; rows 2+3 reconstruct alpha * x
        enc = np.asarray(s.sh[0] + s.sh[1])
        mac = np.asarray(s.sh[2] + s.sh[3])
        assert np.array_equal(mac, np.asarray(alpha) * enc)

    def test_honest_opens_verify(self, x64):
        with mac_scope() as st:
            got = np.asarray(reveal(share(_k(1), jnp.array([3.0, -1.25]),
                                          RING64, "spdz2pc")))
            assert st.n_opened > 0
            st.verify()                      # no abort
        assert np.allclose(got, [3.0, -1.25], atol=1e-3)

    @pytest.mark.parametrize("row", [0, 2], ids=["value-row", "mac-row"])
    def test_single_bit_flip_aborts(self, row, x64):
        """Flip one bit in a value component (row 0) or a MAC component
        (row 2) of the opened tensor: the batched check must abort."""
        x = share(_k(2), jnp.array([1.0, 2.0, 3.0]), RING64, "spdz2pc")
        with mac_scope() as st:
            with tamper_scope(lambda sh: sh.at[row, 1].add(1 << 3)):
                reveal(x)
            with pytest.raises(MacCheckError, match="tampered"):
                st.verify()

    def test_tampered_mul_opening_aborts(self, x64):
        """The adversary corrupts a Beaver (eps, delta) opening instead
        of a final output — still caught: every partial open carries an
        obligation."""
        x = share(_k(3), jnp.ones((4,)), RING64, "spdz2pc")
        with mac_scope() as st:
            with tamper_scope(lambda sh: sh.at[1, 0].add(1)):
                mops.force(mops.mul(x, x, _k(4)), _k(5))
            assert st.n_opened > 0
            with pytest.raises(MacCheckError):
                st.verify()

    def test_honest_mul_chain_verifies(self, x64):
        x = share(_k(6), jnp.array([0.5, -1.5]), RING64, "spdz2pc")
        with mac_scope() as st:
            z = mops.force(mops.mul(x, x, _k(7)), _k(8))
            got = np.asarray(reveal(z))
            assert st.n_opened >= 3          # sacrifice? beaver, trunc, open
            st.verify()
        assert np.allclose(got, [0.25, 2.25], atol=1e-3)

    def test_trunc_requires_key(self, x64):
        x = share(_k(9), jnp.ones((2,)), RING64, "spdz2pc")
        with pytest.raises(ValueError, match="MAC-preserving"):
            protocols.get("spdz2pc").trunc(x, None)


# ---------------------------------------------------------------------------
# 2. the tampered FORWARD aborts at the engine boundary
# ---------------------------------------------------------------------------

class TestForwardAbort:
    def _forward(self, pp, tok):
        pp_sh = proxy_mod.share_proxy(_k(10), pp, RING64, "spdz2pc")
        x = jnp.take(pp["embed"], tok, axis=0) * (CFG.d_model ** 0.5)
        x_sh = share(_k(11), x.astype(jnp.float32), RING64, "spdz2pc")
        eng = MPCEngine(protocol="spdz2pc").with_key(_k(12))
        return proxy_entropy(eng, pp_sh, CFG, x_sh, SPEC, VARIANTS["full"])

    def test_honest_forward_passes_boundary_check(self, pp, tok, x64):
        with mac_scope() as st:
            ent = self._forward(pp, tok)     # entropy_head verifies
            assert st.n_opened > 0
        assert ent.sh.shape[0] == 4

    def test_tampered_forward_aborts_at_entropy_head(self, pp, tok, x64):
        """One flipped bit anywhere in the forward's many partial opens
        is caught by the ONE constant-size check at the output."""
        with mac_scope():
            with tamper_scope(lambda sh: sh.at[0, 0].add(1 << 5)):
                with pytest.raises(MacCheckError, match="aborting"):
                    self._forward(pp, tok)


# ---------------------------------------------------------------------------
# 3. pricing: sacrifice, MAC'd dealer bytes, trunc on BOTH rings
# ---------------------------------------------------------------------------

class TestMaliciousPricing:
    def test_mul_records_sacrifice_and_doubled_triples(self, x64):
        n = 6
        x = share(_k(20), jnp.ones((n,)), RING64, "spdz2pc")
        with ledger_scope() as led:
            mops.mul(x, x, _k(21))
        assert [r.op for r in led.records] == \
            ["offline.mul_triple", "offline.sacrifice_triple",
             "sacrifice", "beaver_mul"]
        assert [r.tag for r in led.records] == \
            ["offline", "offline", "bw", "bw"]
        eb = RING64.elem_bytes
        # MAC'd triples are 4 components/value; sacrifice doubles them
        assert led.offline_nbytes == 2 * (4 * eb * 3 * n)
        # online wire stays semi-honest-sized: value components only
        assert led.records[2].nbytes == led.records[3].nbytes == 4 * eb * n
        assert led.rounds == 2               # sacrifice + beaver open

    @pytest.mark.parametrize("ring", list(RINGS.values()), ids=list(RINGS))
    def test_trunc_pays_dealer_pair_on_both_rings(self, ring, x64):
        """Semi-honest RING64 truncation is free (local shift); the
        MAC'd tier pays a dealer pair + opening round on EVERY ring —
        the malicious overhead curve's RING64 story."""
        x = share(_k(22), jnp.ones((5,)), ring, "spdz2pc")
        p = mops.mul(x, x, _k(23))
        with ledger_scope() as led:
            mops.force(p, _k(24))
        assert [r.op for r in led.records] == \
            ["offline.trunc_pair", "trunc_open"]
        assert led.rounds == 1
        assert led.offline_nbytes == 4 * ring.elem_bytes * 2 * 5
        # semi-honest 2pc at RING64: same force is ledger-silent
        if ring is RING64:
            q = mops.mul(share(_k(25), jnp.ones((5,)), ring, "2pc"),
                         share(_k(26), jnp.ones((5,)), ring, "2pc"),
                         _k(27))
            with ledger_scope() as led2:
                mops.force(q, _k(28))
            assert led2.records == []

    def test_mac_check_flight_is_constant_size(self, x64):
        be = protocols.get("spdz2pc")
        with ledger_scope() as led:
            be.mac_check_flight(RING64)
        assert [r.op for r in led.records] == ["offline.mac_key",
                                               "mac_check"]
        assert led.rounds == 1
        assert led.nbytes == 4 * RING64.elem_bytes        # one combination
        assert led.offline_nbytes == 2 * RING64.elem_bytes

    def test_aby3_trunc2_two_rounds_no_dealer(self, x64):
        x = share(_k(29), jnp.ones((5,)), RING32, "aby3trunc")
        p = mops.mul(x, x, _k(30))
        with ledger_scope() as led:
            mops.force(p, _k(31))
        (rec,) = led.records
        assert rec.op == "trunc2" and rec.rounds == 2
        assert rec.nbytes == 6 * RING32.elem_bytes * 5
        assert led.offline_nbytes == 0


# ---------------------------------------------------------------------------
# 4. full-forward parity: all six variants, both malicious backends
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pp():
    return proxy_mod.random_proxy(K, CFG, SPEC, seq_len=SEQ,
                                  n_classes=CLASSES)


@pytest.fixture(scope="module")
def tok():
    return jnp.asarray(np.random.default_rng(1).integers(
        0, CFG.vocab_size, (BATCH, SEQ)))


class TestMaliciousParity:
    @pytest.mark.parametrize("proto", MALICIOUS)
    @pytest.mark.parametrize("vname", sorted(VARIANTS))
    def test_variant_parity_ring64(self, vname, proto, pp, tok, x64):
        """Acceptance bar: hardening the protocol must not move the
        numbers — both malicious-tier backends match ClearEngine within
        the SEMI-HONEST tolerances on every variant strategy."""
        variant = VARIANTS[vname]
        clear = np.asarray(proxy_entropy(ClearEngine(), pp, CFG, tok,
                                         SPEC, variant))
        pp_sh = proxy_mod.share_proxy(_k(30), pp, RING64, proto)
        x = jnp.take(pp["embed"], tok, axis=0) * (CFG.d_model ** 0.5)
        x_sh = share(_k(31), x.astype(jnp.float32), RING64, proto)
        eng = MPCEngine(protocol=proto).with_key(_k(32))
        got = np.asarray(reveal(proxy_entropy(eng, pp_sh, CFG, x_sh,
                                              SPEC, variant)))
        err = np.abs(got - clear).max()
        assert err < ATOL[vname], (proto, vname, err)


# ---------------------------------------------------------------------------
# 5. analytic mirror + executed malicious phases
# ---------------------------------------------------------------------------

class TestMaliciousMirror:
    @pytest.mark.parametrize("fused", [False, True], ids=["eager", "fused"])
    @pytest.mark.parametrize("ring", list(RINGS.values()), ids=list(RINGS))
    @pytest.mark.parametrize("proto", MALICIOUS)
    def test_probe_matches_mirror(self, proto, ring, fused):
        pp_sh = abstract_shares(CFG, SPEC, SEQ, CLASSES, ring, proto)
        led = TraceEngine(ring, protocol=proto).probe(
            pp_sh, CFG, SPEC, (BATCH, SEQ, CFG.d_model), fused=fused)
        ana = costs.proxy_exec_cost(BATCH, SEQ, CFG.d_model, SPEC.n_heads,
                                    CFG.n_kv_heads, CFG.d_head,
                                    SPEC.mlp_dim, CLASSES, SPEC.n_layers,
                                    ring=ring, protocol=proto, fused=fused)
        assert len(led.records) == len(ana.records)
        for got, want in zip(led.records, ana.records):
            assert (got.rounds, got.nbytes, got.numel, got.flops, got.tag) \
                == (want.rounds, want.nbytes, want.numel, want.flops,
                    want.tag), (proto, got, want)

    def test_overhead_shape(self):
        """The curve bench_fusion emits, asserted at its source: spdz2pc
        pays rounds (trunc no longer free) and dealer bytes over 2pc;
        aby3trunc pays trunc2 rounds over 3pc but stays dealer-free."""
        kw = dict(bsz=BATCH, seq=SEQ, d_model=CFG.d_model,
                  heads=SPEC.n_heads, kv_heads=CFG.n_kv_heads,
                  d_head=CFG.d_head, mlp_hidden=SPEC.mlp_dim,
                  classes=CLASSES, n_layers=SPEC.n_layers)
        base2 = costs.proxy_exec_cost(**kw, ring=RING64, protocol="2pc")
        mal2 = costs.proxy_exec_cost(**kw, ring=RING64, protocol="spdz2pc")
        assert mal2.rounds > base2.rounds
        assert mal2.offline_nbytes > base2.offline_nbytes
        base3 = costs.proxy_exec_cost(**kw, ring=RING32, protocol="3pc")
        mal3 = costs.proxy_exec_cost(**kw, ring=RING32,
                                     protocol="aby3trunc")
        assert mal3.rounds > base3.rounds
        assert mal3.offline_nbytes == base3.offline_nbytes == 0


class TestExecutedMaliciousPhase:
    POOL = 24

    @pytest.fixture(scope="class", params=MALICIOUS)
    def executed(self, request, pp):
        proto = request.param
        pool = np.random.default_rng(0).integers(0, CFG.vocab_size,
                                                 (self.POOL, SEQ))
        out = {"proto": proto}
        for name, fuse in (("eager", False), ("fused", True)):
            ex = WaveExecutor(ExecConfig(wave=2, batch=8, ring=RING64,
                                         protocol=proto, fuse=fuse))
            ent = ex.score_phase(_k(40), pp, CFG, pool, SPEC)
            out[name] = (np.asarray(ent.sh), ex.reports[-1])
        return out

    def test_ledger_agrees(self, executed):
        for name in ("eager", "fused"):
            rep = executed[name][1]
            assert rep.agrees(), (executed["proto"], name)

    def test_party_axis(self, executed):
        assert executed["fused"][0].shape[0] == PARTIES[executed["proto"]]

    def test_malicious_events_in_executed_ledger(self, executed):
        led = executed["eager"][1].ledger
        ops_ = [r.op for r in led.records]
        if executed["proto"] == "spdz2pc":
            assert any(o.endswith("mac_check") for o in ops_)
            assert any(o == "sacrifice" for o in ops_)
            assert led.offline_nbytes > 0
        else:
            assert any(o.endswith("trunc2") for o in ops_)
            assert led.offline_nbytes == 0

    def test_per_batch_matches_mirror(self, executed):
        for name in ("eager", "fused"):
            rep = executed[name][1]
            ana = costs.proxy_exec_cost(8, SEQ, CFG.d_model, SPEC.n_heads,
                                        CFG.n_kv_heads, CFG.d_head,
                                        SPEC.mlp_dim, CLASSES,
                                        SPEC.n_layers, ring=RING64,
                                        protocol=executed["proto"],
                                        fused=rep.fused)
            pb = rep.per_batch
            assert len(pb.records) == len(ana.records), name
            for got, want in zip(pb.records, ana.records):
                assert (got.rounds, got.nbytes, got.numel, got.flops,
                        got.tag) == (want.rounds, want.nbytes, want.numel,
                                     want.flops, want.tag), (name, got, want)

    def test_scores_match_clear(self, executed, pp):
        pool = np.random.default_rng(0).integers(0, CFG.vocab_size,
                                                 (self.POOL, SEQ))
        clear = np.asarray(proxy_entropy(ClearEngine(), pp, CFG,
                                         jnp.asarray(pool), SPEC))
        be = protocols.get(executed["proto"])
        with x64_scope():
            sh = jnp.asarray(executed["fused"][0])
            got = np.asarray(be.reconstruct(sh).astype(jnp.float64)
                             / RING64.scale)
        assert np.abs(got - clear).max() < 1e-3
        assert np.array_equal(executed["eager"][0], executed["fused"][0])


# ---------------------------------------------------------------------------
# 6. wrap statistics: probabilistic vs exact truncation (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestWrapStatistics:
    N = 4096
    SHIFT = 6

    def _values(self):
        # large magnitudes: |enc| up to ~2.46e8 of RING32's 2^31 range,
        # i.e. per-element wrap probability ~|enc|/2^32 up to ~6%
        rng = np.random.default_rng(7)
        return rng.uniform(-6e4, 6e4, self.N).astype(np.float32)

    def _trunc_err(self, proto):
        v = self._values()
        x = share(_k(50), jnp.asarray(v), RING32, proto)
        z = mops.trunc(x, key=_k(51), shift=self.SHIFT)
        assert z.fb == RING32.frac_bits - self.SHIFT
        return np.abs(np.asarray(reveal(z)) - v)

    def test_replicated_trunc_wraps_within_analytic_bound(self):
        """RING32 replicated-3pc probabilistic truncation on this value
        stream MUST wrap (error quantum 2^(32-f) per wrapped element),
        at a rate consistent with the analytic sum(|enc|)/2^32 bound."""
        err = self._trunc_err("3pc")
        wraps = int((err > 1e5).sum())       # quantum is 2^20 ~ 1.05e6
        expected = float(np.abs(self._values()
                                * RING32.scale).sum()) / 2.0 ** 32
        assert wraps > 0, "stream was chosen to wrap measurably"
        assert expected / 5 < wraps < expected * 5, (wraps, expected)
        # non-wrapped elements still meet the ulp bound at fb - shift
        fine = err[err <= 1e5]
        assert fine.max() < 4 * 2.0 ** -(RING32.frac_bits - self.SHIFT)

    def test_aby3_trunc2_zero_wraps_same_stream(self):
        """The exact scheme on the SAME values: no wraps, <= a couple
        ulp of the output exponent — the reason aby3trunc exists."""
        err = self._trunc_err("aby3trunc")
        assert int((err > 1e5).sum()) == 0
        assert err.max() < 4 * 2.0 ** -(RING32.frac_bits - self.SHIFT)
