"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness asserts; prefill/decode consistency vs teacher forcing.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, load_arch, cell_is_applicable
from repro.models import transformer as T

K = jax.random.key(0)


def _batch(cfg, b=2, s=16, with_labels=True, key=K):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_prefix_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = load_arch(arch, smoke=True)
    params = T.init_params(K, cfg)
    batch = _batch(cfg)
    logits, aux = T.forward_logits(params, cfg, batch)
    expect_s = 16 + (cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, grads = jax.value_and_grad(
        lambda p: T.train_loss(p, cfg, batch, remat=False)[0])(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = load_arch(arch, smoke=True)
    params = T.init_params(K, cfg)
    b, s = 2, 16
    toks = jax.random.randint(K, (b, s + 1), 0, cfg.vocab_size)
    prefix = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    batch = _batch(cfg, b, s, with_labels=False)
    batch["tokens"] = toks[:, :s]
    full = dict(batch)
    full["tokens"] = toks
    tf_logits, _ = T.forward_logits(params, cfg, full)

    lg, cache = T.prefill(params, cfg, batch, max_len=prefix + s + 8)
    err_p = float(jnp.abs(lg - tf_logits[:, prefix + s - 1]).max())
    lg2, _ = T.decode_step(params, cfg, cache,
                           {"tokens": toks[:, s:s + 1]},
                           jnp.int32(s + prefix))
    err_d = float(jnp.abs(lg2 - tf_logits[:, prefix + s]).max())
    # bf16-activation archs (hybrid scan path) carry a little more noise
    tol = 2e-2
    assert err_p < tol, f"prefill mismatch {err_p}"
    assert err_d < tol, f"decode mismatch {err_d}"


def test_long_context_decode_ring_buffer():
    """Hybrid local-attn ring buffer: decode far beyond the window."""
    cfg = load_arch("recurrentgemma_2b", smoke=True)
    params = T.init_params(K, cfg)
    b, s = 1, 24                       # window_size is 16 in smoke config
    toks = jax.random.randint(K, (b, s + 8), 0, cfg.vocab_size)
    tf_logits, _ = T.forward_logits(params, cfg, {"tokens": toks})
    _, cache = T.prefill(params, cfg, {"tokens": toks[:, :s]}, max_len=s + 8)
    errs = []
    for j in range(8):
        lg, cache = T.decode_step(params, cfg, cache,
                                  {"tokens": toks[:, s + j:s + j + 1]},
                                  jnp.int32(s + j))
        errs.append(float(jnp.abs(lg - tf_logits[:, s + j]).max()))
    assert max(errs) < 5e-2, errs


def test_moe_load_balance_aux_present():
    cfg = load_arch("phi3_5_moe", smoke=True)
    params = T.init_params(K, cfg)
    _, aux = T.forward_logits(params, cfg, _batch(cfg))
    assert "lb_loss" in aux and float(aux["lb_loss"]) > 0


def test_moe_groups_invariance():
    """Group-local routing must be capacity-equivalent across group counts
    when capacity is dropless."""
    cfg = load_arch("granite_moe_3b", smoke=True)
    params = T.init_params(K, cfg)
    batch = _batch(cfg, b=4, s=16, with_labels=False)
    lg1, _ = T.forward_logits(params, cfg, batch)
    cfg2 = dataclasses.replace(cfg, moe_groups=4)
    lg2, _ = T.forward_logits(params, cfg2, batch)
    assert float(jnp.abs(lg1 - lg2).max()) < 5e-2


def test_cell_applicability_matrix():
    """40 cells: long_500k only for subquadratic families."""
    total = applicable = 0
    for arch in ARCH_IDS:
        cfg = load_arch(arch)
        for s in SHAPES.values():
            total += 1
            ok, why = cell_is_applicable(cfg, s)
            applicable += ok
            if s.name == "long_500k":
                assert ok == (cfg.family in ("ssm", "hybrid")), (arch, why)
    assert total == 40
    # 10 archs x 3 universal shapes + long_500k for the 2 subquadratic
    assert applicable == 10 * 3 + 2


def test_param_count_sanity():
    """Analytic param counts are within 15% of actual init (full configs,
    checked via eval_shape only — no allocation)."""
    for arch in ["qwen2_0_5b", "qwen2_5_32b", "mamba2_2_7b", "phi3_5_moe",
                 "paligemma_3b"]:
        cfg = load_arch(arch)
        shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), K)
        actual = sum(int(np.prod(leaf.shape))
                     for leaf in jax.tree.leaves(shapes))
        est = cfg.param_count()
        assert abs(actual - est) / actual < 0.15, (arch, actual, est)


def test_int8_kv_cache_decode_quality():
    """int8 KV cache (beyond-paper, §Perf): decode logits track the bf16
    teacher-forced path closely; cache buffers really are int8."""
    import dataclasses
    cfg8 = dataclasses.replace(load_arch("qwen2_0_5b", smoke=True),
                               kv_cache_dtype="int8")
    params = T.init_params(K, cfg8)
    B, S = 2, 16
    toks = jax.random.randint(K, (B, S + 2), 0, cfg8.vocab_size)
    tf_logits, _ = T.forward_logits(params, cfg8, {"tokens": toks})
    lg, cache = T.prefill(params, cfg8, {"tokens": toks[:, :S]}, max_len=S + 8)
    assert cache["k"].dtype == jnp.int8 and "ks" in cache
    lg2, cache = T.decode_step(params, cfg8, cache,
                               {"tokens": toks[:, S:S + 1]}, jnp.int32(S))
    want = tf_logits[:, S]
    corr = np.corrcoef(np.asarray(lg2).ravel(), np.asarray(want).ravel())[0, 1]
    assert corr > 0.99, corr
    assert float(jnp.abs(lg2 - want).max()) < 0.2
