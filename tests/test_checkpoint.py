"""Checkpoint durability: COMMIT discipline, corruption skip, exotic
dtypes. The same atomic write-then-COMMIT pattern backs the party
runtime's crash-recovery flight cursor (net/runtime.FlightCursor)."""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree(seed: int):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((4, 3)).astype(np.float32),
            "b": rng.standard_normal(3).astype(np.float32),
            "step": np.asarray(seed, np.int64)}


def _assert_trees_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_restore_picks_newest_commit(tmp_path):
    d = str(tmp_path)
    for step in (1, 5, 3):
        ckpt.save_checkpoint(d, step, _tree(step))
    assert ckpt.latest_step(d) == 5
    got, step = ckpt.restore_checkpoint(d, _tree(0))
    assert step == 5
    _assert_trees_equal(got, _tree(5))


def test_restore_skips_partial_step_missing_commit(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tree(1))
    ckpt.save_checkpoint(d, 2, _tree(2))
    # simulate a crash mid-save of step 3: shard + manifest landed but
    # the COMMIT mark never did
    os.remove(os.path.join(ckpt.save_checkpoint(d, 3, _tree(3)), "COMMIT"))
    assert ckpt.latest_step(d) == 2
    got, step = ckpt.restore_checkpoint(d, _tree(0))
    assert step == 2
    _assert_trees_equal(got, _tree(2))


def test_restore_skips_corrupt_shard_crc(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tree(1))
    step_dir = ckpt.save_checkpoint(d, 2, _tree(2))
    # bitrot in the newest shard: the stored crc no longer matches what
    # the shard's bytes hash to
    mpath = os.path.join(step_dir, "manifest.json")
    manifest = json.load(open(mpath))
    manifest["leaves"][0]["crc32"] ^= 0xDEAD
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    got, step = ckpt.restore_checkpoint(d, _tree(0))
    # newest is COMMITted but corrupt -> restore falls back to step 1
    assert step == 1
    _assert_trees_equal(got, _tree(1))


def test_restore_skips_corrupt_manifest_json(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tree(1))
    step_dir = ckpt.save_checkpoint(d, 2, _tree(2))
    with open(os.path.join(step_dir, "manifest.json"), "w") as f:
        f.write("{ not json")
    got, step = ckpt.restore_checkpoint(d, _tree(0))
    assert step == 1


def test_exotic_dtype_uint_view_roundtrip(tmp_path):
    ml_dtypes = pytest.importorskip("ml_dtypes")
    d = str(tmp_path)
    rng = np.random.default_rng(0)
    tree = {
        "bf16": rng.standard_normal((3, 5)).astype(ml_dtypes.bfloat16),
        "fp8": rng.standard_normal(7).astype(ml_dtypes.float8_e4m3fn),
        "f32": rng.standard_normal(4).astype(np.float32),
    }
    ckpt.save_checkpoint(d, 1, tree)
    # on disk the exotic leaves are uint views, logical dtype recorded
    step_dir = os.path.join(d, "step_00000001")
    manifest = json.load(open(os.path.join(step_dir, "manifest.json")))
    by_logical = {e["logical_dtype"]: e["dtype"] for e in manifest["leaves"]}
    assert by_logical["bfloat16"] == "uint16"
    assert by_logical["float8_e4m3fn"] == "uint8"
    got, step = ckpt.restore_checkpoint(d, tree)
    assert step == 1
    for k in tree:
        assert got[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(
            np.asarray(got[k]).view(np.uint8),
            np.asarray(tree[k]).view(np.uint8))


def test_gc_keeps_newest_k(tmp_path):
    d = str(tmp_path)
    for step in range(1, 6):
        ckpt.save_checkpoint(d, step, _tree(step), keep=2)
    steps = sorted(ckpt._steps(d))
    assert steps == [4, 5]
