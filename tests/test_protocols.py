"""Protocol backends: replicated 2-of-3 sharing kills the trusted dealer.

Contracts:
  1. BACKEND CORRECTNESS — replicated-3PC share/open/mul/matmul/trunc
     reconstruct the same values as the 2PC additive backend, with the
     scheme's own wire model (3 * elem_bytes opens, output-proportional
     resharing flights) and ZERO dealer events.
  2. OFFLINE CHANNEL — 2PC dealer bytes (Beaver triples, trunc pairs)
     land under tag="offline": excluded from Ledger.nbytes/makespan,
     reported via offline_nbytes, mirrored by the analytic formulas.
  3. FORWARD PARITY — a full RING64 3PC proxy forward matches
     ClearEngine within the same tolerance the 2PC path holds, for all
     six variant strategies.
  4. MIRROR + EXECUTION — costs.proxy_exec_cost(protocol="3pc") mirrors
     the probed/executed stream record-for-record; an executed 3PC
     phase passes iosched.ledger_agrees with no offline/dealer event
     (the ISSUE's acceptance criterion).
  5. SHAPE OPS ACROSS BACKENDS — broadcast with negative/padded axes,
     moveaxis/swapaxes/index with negative indices, and scalar shares
     agree with ClearEngine on BOTH backends (the PR 2 qkv_bias
     party-axis bug class, previously pinned only for 2PC).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_targets import TINY_TARGET
from repro.core import proxy as proxy_mod
from repro.core.executor import ExecConfig, WaveExecutor
from repro.core.proxy import ProxySpec
from repro.engine import (ClearEngine, MPCEngine, TraceEngine, VARIANTS,
                          abstract_shares, proxy_entropy, resolve_engine)
from repro.mpc import costs, ops as mops, compare, protocols
from repro.mpc.comm import ledger_scope
from repro.mpc.ring import RING32, RING64, x64_scope
from repro.mpc.sharing import reveal, share

CFG = dataclasses.replace(TINY_TARGET, vocab_size=64, n_layers=1,
                          d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                          d_ff=64)
SPEC = ProxySpec(1, 2, 4)
SEQ, BATCH, CLASSES = 8, 6, 3
K = jax.random.key(0)

# same per-variant tolerances the 2PC parity sweep holds (test_engine.py)
ATOL = {"full": 2e-3, "no-sm": 2e-2, "no-ln": 2e-2, "no-se": 6e-2,
        "quad_sm": 2e-2, "poly_sm": 2e-2}

RINGS = {"ring64": RING64, "ring32": RING32}
PROTOS = ("2pc", "3pc")


def _k(i):
    return jax.random.fold_in(K, i)


# ---------------------------------------------------------------------------
# 1. backend primitives
# ---------------------------------------------------------------------------

class TestReplicatedSharing:
    def test_registry(self):
        assert protocols.get("2pc").n_parties == 2
        assert protocols.get("3pc").n_parties == 3
        with pytest.raises(ValueError, match="unknown protocol"):
            protocols.get("4pc")

    def test_share_roundtrip_and_layout(self, x64):
        x = jnp.array([1.5, -2.25, 1000.0, -0.0001, 0.0])
        s = share(_k(0), x, RING64, "3pc")
        assert s.sh.shape == (3, 5) and s.n_parties == 3
        assert s.proto == "3pc"
        assert np.allclose(np.asarray(reveal(s)), x, atol=1e-3)

    def test_single_component_is_uniform(self, x64):
        """Any lone component must carry no information (2-of-3: one
        party's PAIR of components is two independent uniforms)."""
        x = jnp.full((4096,), 7.25)
        s = share(_k(1), x, RING64, "3pc")
        for i in range(3):
            comp = np.asarray(s.sh[i], dtype=np.float64)
            assert np.std(comp) > 2 ** 60, i

    def test_open_wire_model(self, x64):
        """open_ no longer hard-codes 2 * elem_bytes: bytes follow the
        backend's party count."""
        x = jnp.ones((10,))
        for proto, parties in (("2pc", 2), ("3pc", 3)):
            with ledger_scope() as led:
                reveal(share(_k(2), x, RING64, proto))
            (rec,) = led.records
            assert rec.nbytes == parties * RING64.elem_bytes * 10, proto

    @pytest.mark.parametrize("ring", list(RINGS.values()), ids=list(RINGS))
    def test_mul_matches_2pc_values(self, ring, x64):
        x = jnp.array([1.5, -2.0, 0.25, 3.0], jnp.float32)
        y = jnp.array([2.0, 1.5, -4.0, 0.5], jnp.float32)
        got = reveal(mops.mul(share(_k(3), x, ring, "3pc"),
                              share(_k(4), y, ring, "3pc"), _k(5)))
        assert np.allclose(np.asarray(got), x * y,
                           atol=8.0 / ring.scale * (1 + 8))

    def test_matmul_and_relu(self, x64):
        a = jax.random.normal(_k(6), (5, 7))
        b = jax.random.normal(_k(7), (7, 3))
        z = reveal(mops.matmul(share(_k(8), a, RING64, "3pc"),
                               share(_k(9), b, RING64, "3pc"), _k(10)))
        assert np.allclose(np.asarray(z), np.asarray(a @ b), atol=1e-3)
        x = jnp.array([-3.0, -0.5, 0.0, 0.5, 3.0])
        r = reveal(compare.relu(share(_k(11), x, RING64, "3pc"), _k(12)))
        assert np.allclose(np.asarray(r), np.maximum(x, 0), atol=1e-3)

    def test_public_ops_preserve_proto(self, x64):
        x = jnp.array([1.0, -2.0, 3.0])
        xs = share(_k(13), x, RING64, "3pc")
        for out, want in ((mops.add_public(xs, 2.5), x + 2.5),
                          (mops.mul_public(xs, -1.5), x * -1.5),
                          (mops.mul_public_int(xs, 3), x * 3),
                          (mops.neg(xs), -x)):
            assert out.proto == "3pc" and out.n_parties == 3
            assert np.allclose(np.asarray(reveal(out)), want, atol=1e-3)

    def test_mixed_protocol_inputs_rejected(self, x64):
        x2 = share(_k(14), jnp.ones((4,)), RING64, "2pc")
        eng = MPCEngine(protocol="3pc").with_key(_k(15))
        with pytest.raises(ValueError, match="protocol"):
            eng.embed(None, x2, CFG)


# ---------------------------------------------------------------------------
# 2. the offline dealer channel
# ---------------------------------------------------------------------------

class TestOfflineChannel:
    def test_2pc_mul_records_dealer_bytes(self, x64):
        """A scale-carrying mul records the triple + opening only; the
        dealer trunc pair arrives when a consumer FORCES the carried
        2f exponent (mpc/scale.py) — one pair per forced value."""
        x = share(_k(20), jnp.ones((6,)), RING32)
        y = share(_k(21), jnp.ones((6,)), RING32)
        with ledger_scope() as led:
            z = mops.mul(x, y, _k(22))
            assert z.excess == RING32.frac_bits     # rides at 2f
            zc = mops.force(z, _k(23))
            assert zc.excess == 0
            # the force memo: a second consumer pays nothing
            assert mops.force(z, _k(24)) is zc
        tags = [r.tag for r in led.records]
        assert tags == ["offline", "bw", "offline", "bw"]
        # triple: 3 tensors of 6 elems; trunc pair: 2 tensors of 6
        assert led.offline_nbytes == 2 * RING32.elem_bytes * (18 + 12)
        # offline bytes are NOT online wire bytes
        assert led.nbytes == sum(r.nbytes for r in led.records
                                 if r.tag == "bw")
        # and offline rounds are zero: the round count is online-only
        assert led.rounds == 2

    def test_3pc_has_zero_offline(self, x64):
        x = share(_k(23), jnp.ones((6,)), RING32, "3pc")
        with ledger_scope() as led:
            z = mops.mul(x, x, _k(24))
            mops.matmul(z.reshape(2, 3), share(_k(25), jnp.ones((3, 2)),
                                               RING32, "3pc"), _k(26))
        assert led.offline_nbytes == 0
        assert all(r.tag != "offline" for r in led.records)

    def test_triple_bytes_helper(self):
        from repro.mpc import beaver
        assert beaver.triple_bytes((4,), (4,), (4,), RING64) == \
            2 * 8 * 12


# ---------------------------------------------------------------------------
# 3. full-forward clear/MPC parity on the dealer-free backend
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pp():
    return proxy_mod.random_proxy(K, CFG, SPEC, seq_len=SEQ,
                                  n_classes=CLASSES)


@pytest.fixture(scope="module")
def tok():
    return jnp.asarray(np.random.default_rng(1).integers(
        0, CFG.vocab_size, (BATCH, SEQ)))


class Test3PCParity:
    @pytest.mark.parametrize("vname", sorted(VARIANTS))
    def test_variant_parity_ring64(self, vname, pp, tok, x64):
        """The acceptance bar: RING64 3PC matches ClearEngine within the
        tolerance the 2PC path holds, on every variant strategy."""
        variant = VARIANTS[vname]
        clear = np.asarray(proxy_entropy(ClearEngine(), pp, CFG, tok,
                                         SPEC, variant))
        pp_sh = proxy_mod.share_proxy(_k(30), pp, RING64, "3pc")
        x = jnp.take(pp["embed"], tok, axis=0) * (CFG.d_model ** 0.5)
        x_sh = share(_k(31), x.astype(jnp.float32), RING64, "3pc")
        eng = MPCEngine(protocol="3pc").with_key(_k(32))
        got = np.asarray(reveal(proxy_entropy(eng, pp_sh, CFG, x_sh,
                                              SPEC, variant)))
        err = np.abs(got - clear).max()
        assert err < ATOL[vname], (vname, err)


# ---------------------------------------------------------------------------
# 4. analytic mirror + executed 3PC phase (the acceptance criterion)
# ---------------------------------------------------------------------------

class Test3PCMirror:
    @pytest.mark.parametrize("fused", [False, True], ids=["eager", "fused"])
    @pytest.mark.parametrize("ring", list(RINGS.values()), ids=list(RINGS))
    def test_probe_matches_mirror(self, ring, fused):
        pp_sh = abstract_shares(CFG, SPEC, SEQ, CLASSES, ring, "3pc")
        led = TraceEngine(ring, protocol="3pc").probe(
            pp_sh, CFG, SPEC, (BATCH, SEQ, CFG.d_model), fused=fused)
        ana = costs.proxy_exec_cost(BATCH, SEQ, CFG.d_model, SPEC.n_heads,
                                    CFG.n_kv_heads, CFG.d_head,
                                    SPEC.mlp_dim, CLASSES, SPEC.n_layers,
                                    ring=ring, protocol="3pc", fused=fused)
        assert led.offline_nbytes == 0 and ana.offline_nbytes == 0
        assert len(led.records) == len(ana.records)
        for got, want in zip(led.records, ana.records):
            assert (got.rounds, got.nbytes, got.numel, got.flops, got.tag) \
                == (want.rounds, want.nbytes, want.numel, want.flops,
                    want.tag), (got, want)

    def test_3pc_trunc_free_on_ring32(self):
        """The dealer's other product — trunc pairs — is gone too: the
        3pc RING32 stream has no trunc_open rounds, so it pays exactly
        the RING64 3pc round count."""
        kw = dict(bsz=BATCH, seq=SEQ, d_model=CFG.d_model,
                  heads=SPEC.n_heads, kv_heads=CFG.n_kv_heads,
                  d_head=CFG.d_head, mlp_hidden=SPEC.mlp_dim,
                  classes=CLASSES, n_layers=SPEC.n_layers)
        l32 = costs.proxy_exec_cost(**kw, ring=RING32, protocol="3pc")
        l64 = costs.proxy_exec_cost(**kw, ring=RING64, protocol="3pc")
        assert l32.rounds == l64.rounds
        two32 = costs.proxy_exec_cost(**kw, ring=RING32, protocol="2pc")
        assert two32.rounds > l32.rounds          # dealer truncs gone
        assert two32.offline_nbytes > 0 == l32.offline_nbytes


class TestExecuted3PCPhase:
    POOL = 24

    @pytest.fixture(scope="class")
    def executed(self, pp):
        pool = np.random.default_rng(0).integers(0, CFG.vocab_size,
                                                 (self.POOL, SEQ))
        out = {}
        for name, fuse in (("eager", False), ("fused", True)):
            ex = WaveExecutor(ExecConfig(wave=2, batch=8, ring=RING64,
                                         protocol="3pc", fuse=fuse))
            ent = ex.score_phase(_k(40), pp, CFG, pool, SPEC)
            out[name] = (np.asarray(ent.sh), ex.reports[-1])
        return out

    def test_ledger_agrees_and_no_dealer(self, executed):
        """Acceptance: an executed RING64 replicated-3PC phase passes
        ledger_agrees with ZERO dealer/offline events."""
        for name, (_, rep) in executed.items():
            assert rep.agrees(), name
            assert rep.ledger.offline_nbytes == 0, name
            bad = [r.op for r in rep.ledger.records
                   if r.tag == "offline" or r.op.startswith("offline")
                   or r.op.startswith("beaver")
                   or r.op.startswith("trunc_open")]
            assert not bad, (name, bad)

    def test_party_axis_is_three(self, executed):
        assert executed["fused"][0].shape[0] == 3

    def test_fusion_moves_flights_not_values(self, executed):
        assert np.array_equal(executed["eager"][0], executed["fused"][0])
        led_e = executed["eager"][1].ledger
        led_f = executed["fused"][1].ledger
        assert led_f.rounds < led_e.rounds
        assert led_f.nbytes == led_e.nbytes

    def test_per_batch_matches_mirror(self, executed):
        for name, (_, rep) in executed.items():
            ana = costs.proxy_exec_cost(8, SEQ, CFG.d_model, SPEC.n_heads,
                                        CFG.n_kv_heads, CFG.d_head,
                                        SPEC.mlp_dim, CLASSES,
                                        SPEC.n_layers, ring=RING64,
                                        protocol="3pc", fused=rep.fused)
            pb = rep.per_batch
            assert len(pb.records) == len(ana.records), name
            for got, want in zip(pb.records, ana.records):
                assert (got.rounds, got.nbytes, got.numel, got.flops,
                        got.tag) == (want.rounds, want.nbytes, want.numel,
                                     want.flops, want.tag), (name, got, want)

    def test_3pc_scores_match_clear(self, executed, pp):
        from repro.mpc.sharing import reconstruct
        pool = np.random.default_rng(0).integers(0, CFG.vocab_size,
                                                 (self.POOL, SEQ))
        clear = np.asarray(proxy_entropy(ClearEngine(), pp, CFG,
                                         jnp.asarray(pool), SPEC))
        with x64_scope():
            sh = jnp.asarray(executed["fused"][0])
            got = np.asarray(reconstruct(sh).astype(jnp.float64)
                             / RING64.scale)
        assert np.abs(got - clear).max() < 1e-3


# ---------------------------------------------------------------------------
# 5. share shape ops across backends (the qkv_bias bug class)
# ---------------------------------------------------------------------------

class TestShapeOpsAcrossBackends:
    """Every engine shape op vs the ClearEngine reference, on both
    protocol backends — negative axes, padded broadcasts, scalar
    shares. The party axis must never be confused with a value dim
    regardless of its size."""

    def _pair(self, proto, val, i=50):
        eng = MPCEngine(protocol=proto).with_key(_k(i))
        s = share(_k(i + 1), jnp.asarray(val, jnp.float32), RING64, proto)
        return eng, s

    @pytest.mark.parametrize("proto", PROTOS)
    def test_broadcast_padded_axes(self, proto, x64):
        ceng = ClearEngine()
        v = np.arange(4.0)
        eng, s = self._pair(proto, v)
        out = eng.broadcast(s, (3, 4))
        assert out.shape == (3, 4) and out.n_parties == eng.backend.n_parties
        want = ceng.broadcast(jnp.asarray(v), (3, 4))
        assert np.allclose(np.asarray(reveal(out)), np.asarray(want),
                           atol=1e-3)

    @pytest.mark.parametrize("proto", PROTOS)
    def test_broadcast_scalar_share(self, proto, x64):
        eng, s = self._pair(proto, 2.5, 52)
        out = eng.broadcast(s, (2, 3))
        assert out.shape == (2, 3)
        assert np.allclose(np.asarray(reveal(out)), 2.5, atol=1e-3)

    @pytest.mark.parametrize("proto", PROTOS)
    def test_moveaxis_swapaxes_negative(self, proto, x64):
        ceng = ClearEngine()
        v = np.random.default_rng(3).normal(size=(2, 3, 4))
        eng, s = self._pair(proto, v, 54)
        for fn, args in (("moveaxis", (-1, 0)), ("moveaxis", (1, -1)),
                         ("swapaxes", (-1, -2)), ("swapaxes", (0, -1))):
            got = getattr(eng, fn)(s, *args)
            want = getattr(ceng, fn)(jnp.asarray(v), *args)
            assert got.shape == tuple(want.shape), (fn, args)
            assert np.allclose(np.asarray(reveal(got)), np.asarray(want),
                               atol=1e-3), (fn, args)

    @pytest.mark.parametrize("proto", PROTOS)
    def test_index_negative_and_getitem(self, proto, x64):
        v = np.random.default_rng(4).normal(size=(5, 3))
        eng, s = self._pair(proto, v, 56)
        for i in (0, 2, -1, -5):
            got = eng.index(s, i)
            assert got.shape == (3,)
            assert np.allclose(np.asarray(reveal(got)), v[i], atol=1e-3), i
        sub = s[1:4]
        assert sub.shape == (3, 3) and sub.proto == proto
        assert np.allclose(np.asarray(reveal(sub)), v[1:4], atol=1e-3)

    @pytest.mark.parametrize("proto", PROTOS)
    def test_reshape_and_sum_negative_axis(self, proto, x64):
        v = np.random.default_rng(5).normal(size=(4, 6))
        eng, s = self._pair(proto, v, 58)
        r = eng.reshape(s, (2, 2, 6))
        assert r.shape == (2, 2, 6)
        tot = mops.sum_(r, axis=-1)
        assert tot.shape == (2, 2)
        assert np.allclose(np.asarray(reveal(tot)),
                           v.reshape(2, 2, 6).sum(-1), atol=1e-3)

    @pytest.mark.parametrize("proto", PROTOS)
    def test_qkv_bias_broadcast_regression(self, proto, x64):
        """The PR 2 party-axis bug, now pinned for BOTH backends: a
        (P, n)-share broadcast to (rows, n) must right-align the value
        dims, not glue the party axis onto a value dim."""
        b = np.random.default_rng(6).normal(size=(8,))
        eng, s = self._pair(proto, b, 60)
        out = eng.broadcast(s, (6, 8))
        assert np.allclose(np.asarray(reveal(out)),
                           np.broadcast_to(b, (6, 8)), atol=1e-3)


# ---------------------------------------------------------------------------
# resolution plumbing
# ---------------------------------------------------------------------------

class TestProtocolResolution:
    def test_resolve_engine_protocol(self):
        eng = resolve_engine("mpc", ring=RING32, protocol="3pc")
        assert isinstance(eng, MPCEngine)
        assert eng.protocol == "3pc" and eng.backend.n_parties == 3
        tr = resolve_engine("trace", protocol="3pc")
        assert tr.protocol == "3pc"

    def test_selection_config_syncs_protocol(self):
        from repro.core.selection import SelectionConfig
        sel = SelectionConfig(phases=[SPEC], engine=MPCEngine(
            RING64, protocol="3pc"))
        assert sel.executor.protocol == "3pc"
        sel2 = SelectionConfig(phases=[SPEC], mode="mpc",
                               executor=ExecConfig(protocol="3pc"))
        assert sel2.engine.protocol == "3pc"

    def test_share_pytree_roundtrip(self, x64):
        s = share(_k(70), jnp.ones((2, 2)), RING64, "3pc")
        leaves, treedef = jax.tree.flatten(s)
        s2 = jax.tree.unflatten(treedef, leaves)
        assert s2.proto == "3pc" and s2.ring is RING64
        assert np.array_equal(np.asarray(s.sh), np.asarray(s2.sh))
